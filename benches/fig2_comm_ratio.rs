//! Bench harness for **Fig. 2**: CSGD training time vs Allreduce time
//! per step (and their ratio) as workers scale 4 → 256.
//!
//! Paper shape to reproduce: total communication time *grows* with N
//! (α-dominated ring) while per-epoch iteration count falls; the
//! allreduce/train ratio increases roughly linearly past 64 workers —
//! the stated reason CSGD stops scaling.
//!
//! Run: `cargo bench --bench fig2_comm_ratio`

use lsgd::metrics::{FigureSeries, ScalingRow};
use lsgd::simnet::{self, AllreduceAlgo, ClusterModel};
use lsgd::topology::Topology;
use lsgd::util::bench::Harness;

fn main() {
    let m = ClusterModel::paper_k80();
    let mut series = FigureSeries::new("Fig. 2 — CSGD train vs Allreduce time per step (paper-calibrated)");
    println!("{:>8} {:>10} {:>12} {:>12} {:>9}", "workers", "epoch_its", "allreduce_s", "step_s", "ratio");
    for g in [1usize, 2, 4, 8, 16, 32, 64] {
        let topo = Topology::new(g, 4).unwrap();
        let s = simnet::step_time_csgd(&m, &topo);
        let n = topo.num_workers();
        // ImageNet: 1.28M images / (64·N) iterations per epoch
        let iters_per_epoch = 1_281_167 / (64 * n);
        println!(
            "{:>8} {:>10} {:>12.4} {:>12.4} {:>9.3}",
            n,
            iters_per_epoch,
            s.global_allreduce,
            s.total,
            s.global_allreduce / s.total
        );
        series.push(ScalingRow {
            workers: n,
            groups: g,
            algo: "csgd".into(),
            step_seconds: s.total,
            throughput: simnet::throughput(&m, &topo, s.total),
            comm_seconds: s.global_allreduce,
            comm_fraction: s.global_allreduce / s.total,
            efficiency_pct: 0.0,
        });
    }
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/fig2.csv", series.to_csv()).unwrap();
    println!("→ bench_results/fig2.csv");

    // ablation: the same sweep under recursive halving-doubling shows
    // the ratio collapse — the baseline's weakness is algorithmic
    let mut m2 = m.clone();
    m2.algo = AllreduceAlgo::RecursiveHalvingDoubling;
    let s_ring = simnet::step_time_csgd(&m, &Topology::new(64, 4).unwrap());
    let s_rhd = simnet::step_time_csgd(&m2, &Topology::new(64, 4).unwrap());
    println!(
        "\nablation @256 workers: ring allreduce {:.3}s vs RHD {:.3}s",
        s_ring.global_allreduce, s_rhd.global_allreduce
    );

    // micro-bench the model evaluation itself (it sits inside every
    // sweep loop of the figure harness)
    let mut h = Harness::quick();
    let topo = Topology::new(64, 4).unwrap();
    h.bench("step_time_csgd/eval", || simnet::step_time_csgd(&m, &topo));
    h.bench("step_time_lsgd/eval", || simnet::step_time_lsgd(&m, &topo));
}
