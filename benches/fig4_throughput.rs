//! Bench harness for **Fig. 4** (throughput, LSGD vs CSGD) and
//! **Fig. 5** (their ratio) over the paper's 4 → 256 worker sweep.
//!
//! Paper shape to reproduce:
//!   * CSGD is slightly FASTER at 1–2 nodes (LSGD pays two-layer
//!     communication overhead);
//!   * crossover, then LSGD's throughput stays near-linear while
//!     CSGD's flattens;
//!   * at 256 workers LSGD ≈ 1.42× CSGD (93.1 % vs 63.8 % efficiency).
//!
//! The sweep runs the discrete-event simulator (not just the closed
//! form), so dependency resolution and the overlap window are
//! exercised at every point.
//!
//! Run: `cargo bench --bench fig4_throughput`

use lsgd::metrics::{FigureSeries, ScalingRow};
use lsgd::simnet::{self, des, ClusterModel};
use lsgd::topology::Topology;
use lsgd::util::bench::Harness;

fn main() {
    let m = ClusterModel::paper_k80();
    let steps = 8;
    let mut fig4 = FigureSeries::new("Fig. 4 — throughput (samples/s), DES-played");
    let mut fig5 = FigureSeries::new("Fig. 5 — LSGD/CSGD throughput ratio");
    for g in [1usize, 2, 4, 8, 16, 32, 64] {
        let topo = Topology::new(g, 4).unwrap();
        let n = topo.num_workers();
        let c_step = des::per_step(&des::run_csgd(&m, &topo, steps), steps);
        let l_step = des::per_step(&des::run_lsgd(&m, &topo, steps), steps);
        let c_thr = simnet::throughput(&m, &topo, c_step);
        let l_thr = simnet::throughput(&m, &topo, l_step);
        for (algo, st, thr) in [("csgd", c_step, c_thr), ("lsgd", l_step, l_thr)] {
            fig4.push(ScalingRow {
                workers: n,
                groups: g,
                algo: algo.into(),
                step_seconds: st,
                throughput: thr,
                comm_seconds: 0.0,
                comm_fraction: 0.0,
                efficiency_pct: 0.0,
            });
        }
        fig5.push(ScalingRow {
            workers: n,
            groups: g,
            algo: "l/c".into(),
            step_seconds: l_step / c_step,
            throughput: l_thr / c_thr,
            comm_seconds: 0.0,
            comm_fraction: 0.0,
            efficiency_pct: 100.0 * l_thr / c_thr,
        });
    }
    print!("{}", fig4.to_table());
    println!();
    print!("{}", fig5.to_table());
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/fig4.csv", fig4.to_csv()).unwrap();
    std::fs::write("bench_results/fig5.csv", fig5.to_csv()).unwrap();
    println!("→ bench_results/fig4.csv, bench_results/fig5.csv");

    // the paper's qualitative checkpoints, asserted
    let r8 = fig5.rows[1].throughput;
    let r256 = fig5.rows[6].throughput;
    assert!(r8 < 1.0, "LSGD should trail at 8 workers (got ratio {r8:.3})");
    assert!(r256 > 1.3, "LSGD should lead at 256 workers (got ratio {r256:.3})");
    println!("shape checks OK: ratio@8={r8:.3} (<1), ratio@256={r256:.3} (>1.3)");

    // ablation: stragglers — synchronous SGD (both schedules!) pays the
    // max of per-group compute jitter at every barrier; the penalty
    // approaches the full jitter bound as groups grow (E[max of G
    // uniforms] → 1). Neither the paper's CSGD nor LSGD mitigates this;
    // the DES quantifies it.
    println!("\n# ablation — straggler jitter (compute × (1 + j·U[0,1)) per group/step)");
    println!("{:>8} {:>8} {:>14} {:>14}", "workers", "jitter", "csgd_slowdown", "lsgd_slowdown");
    for g in [2usize, 16, 64] {
        let topo = Topology::new(g, 4).unwrap();
        for j in [0.1, 0.3] {
            let c0 = des::per_step(&des::run_csgd(&m, &topo, steps), steps);
            let cj = des::per_step(&des::run_csgd_jittered(&m, &topo, steps, j), steps);
            let l0 = des::per_step(&des::run_lsgd(&m, &topo, steps), steps);
            let lj = des::per_step(&des::run_lsgd_jittered(&m, &topo, steps, j), steps);
            println!("{:>8} {:>8.2} {:>13.1}% {:>13.1}%", g * 4, j, 100.0 * (cj / c0 - 1.0), 100.0 * (lj / l0 - 1.0));
        }
    }

    // DES cost itself (it's the inner loop of this harness)
    let mut h = Harness::quick();
    let topo = Topology::new(64, 4).unwrap();
    h.bench("des::run_lsgd/64x4/8steps", || des::run_lsgd(&m, &topo, 8).makespan);
    h.bench("des::run_csgd/64x4/8steps", || des::run_csgd(&m, &topo, 8).makespan);
}
