//! Bench guard: the datacenter-scale DES hot paths stay fast.
//!
//! These rows lock in the three rearchitected paths: the calendar
//! event queue (a 64-group jittered LSGD run is queue-bound), the
//! incremental max–min allocator (the routed global allreduce at
//! thousands of communicator lanes re-solves only touched components),
//! and the arena packet replay (a flat-ring step at p ≥ 1024 is
//! millions of messages with no per-message allocation). Smoke mode
//! (`BENCH_SMOKE=1`) shrinks the sizes so CI's `bench-smoke` job stays
//! fast while `benches/baseline.json` keeps ceilings on the full rows.
//!
//! Run: `cargo bench --bench des_scale`

use lsgd::simnet::{des, AllreduceAlgo, ClusterModel, NetConfig, NetModel, PerturbConfig};
use lsgd::topology::Topology;
use lsgd::util::bench::{enforce_baseline_from_env, smoke_mode, Harness};

fn main() {
    let smoke = smoke_mode();
    let mut h = if smoke { Harness::quick() } else { Harness::default() };
    println!("# des_scale — datacenter-size DES hot paths");

    let mut m = ClusterModel::paper_k80();
    m.algo = AllreduceAlgo::RecursiveHalvingDoubling;

    // closed-form fabric mode at many groups: the routed RHD global
    // allreduce prices G concurrent lane streams per round through the
    // incremental allocator (smoke: 256 groups, full: 4096 = 65,536
    // ranks)
    let groups = if smoke { 256 } else { 4096 };
    let topo = Topology::new(groups, 16).unwrap();
    let mut p = PerturbConfig::default();
    p.fabric = "2tier:2".parse().unwrap();
    p.trace = false;
    h.bench(&format!("des_scale/lsgd_2tier_step/{groups}x16"), || {
        des::run_lsgd_perturbed(&m, &topo, 1, &p).unwrap().makespan
    });

    // packet replay over private links: a flat-ring CSGD step is
    // 2(p-1) rounds of p messages (smoke: p = 256 ≈ 130 k msgs, full:
    // p = 1024 ≈ 2.1 M msgs) — the arena/no-alloc message path
    let pg = if smoke { 16 } else { 64 };
    let topo2 = Topology::new(pg, 16).unwrap();
    let m2 = ClusterModel::paper_k80();
    let net = NetConfig { model: NetModel::Packet, jitter: 0.05, reorder: 0.01, chunk: 1 };
    h.bench(&format!("des_scale/csgd_packet_step/{}", pg * 16), || {
        des::run_csgd_net(&m2, &topo2, 1, &net, 0x57A6).unwrap().makespan
    });

    // event-queue pressure: jittered lanes desynchronize, so the
    // calendar queue sees scattered timestamps instead of lockstep
    // barriers (smoke: 64 groups, full: 512)
    let jg = if smoke { 64 } else { 512 };
    let topo3 = Topology::new(jg, 4).unwrap();
    h.bench(&format!("des_scale/lsgd_jittered/{jg}x4x5"), || {
        des::run_lsgd_jittered(&m2, &topo3, 5, 0.3).makespan
    });

    println!("\n{}", h.csv());
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/BENCH_des_scale.json", h.json()).unwrap();
    println!("→ bench_results/BENCH_des_scale.json");
    enforce_baseline_from_env(&h.results);
}
