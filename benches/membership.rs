//! Bench guard: elastic `Membership` lookups must stay sub-quadratic.
//!
//! PR 2's `shard_range` walked `alive().position()` — O(N) per worker,
//! O(N²) for the per-step all-worker shard resolution — and `locate`
//! linearly scanned the groups. Both are now binary searches over
//! cached group-boundary offsets; these benches gate the whole-cluster
//! lookup pattern (every alive worker resolves its shard, as the
//! engine does each segment) so an accidental return to linear scans
//! fails CI's `bench-smoke` ceilings in `benches/baseline.json` (only
//! the 4096-worker rows are gated — at that size the quadratic path is
//! tens of milliseconds, far past any machine-speed headroom).
//!
//! Run: `cargo bench --bench membership`

use lsgd::topology::{Membership, Topology, WorkerId};
use lsgd::util::bench::{enforce_baseline_from_env, smoke_mode, Harness};

/// A realistic post-fault membership: a few scattered removals, then a
/// rebalance (uneven ascending runs, offsets in play).
fn membership(groups: usize, wpg: usize) -> Membership {
    let topo = Topology::new(groups, wpg).unwrap();
    let mut m = topo.membership();
    for w in [1usize, 7, 13] {
        m.remove_worker(WorkerId(w)).unwrap();
    }
    m.rebalance();
    m
}

fn main() {
    let smoke = smoke_mode();
    let mut h = if smoke { Harness::quick() } else { Harness::default() };
    println!("# membership — elastic lookup hot path");

    for &(groups, wpg) in &[(64usize, 4usize), (1024, 4)] {
        let m = membership(groups, wpg);
        let n = m.num_workers();
        let gb = n * 8; // 8 samples per alive worker
        let label = groups * wpg;
        h.bench(&format!("membership/shard_range_all/{label}"), || {
            let mut acc = 0usize;
            for w in m.alive() {
                acc += m.shard_range(w, gb).unwrap().start;
            }
            acc
        });
        h.bench(&format!("membership/locate_all/{label}"), || {
            let mut acc = 0usize;
            for w in m.alive() {
                acc += m.locate(w).unwrap().1;
            }
            acc
        });
    }

    // mutation cost at scale: build + scattered removals + rebalance
    h.bench("membership/rebuild_remove_rebalance/4096", || {
        let m = membership(1024, 4);
        m.num_workers()
    });

    println!("\n{}", h.csv());
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/BENCH_membership.json", h.json()).unwrap();
    println!("→ bench_results/BENCH_membership.json");
    enforce_baseline_from_env(&h.results);
}
