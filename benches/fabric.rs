//! Bench guard: shared-fabric contention pricing must stay cheap
//! enough to fair-share every round of a packet DES step.
//!
//! Two hot paths: `fabric::max_min_rates` (the water-filling solve —
//! called once per replayed round) and the routed DES steps, where the
//! 256-rank flat ring is the worst case (510 rounds × 256 flows ×
//! progressive filling each). The `*_2tier_step` rows replay whole DES
//! steps contended (oversub 2) so a regression in the allocator, the
//! route builders, or the per-round `run_flows` loop shows up where it
//! is actually paid — contrast with the uncontended `netsim/*_step`
//! rows, which replay the same schedules on private links. Ceilings
//! live in `benches/baseline.json`, enforced by CI's `bench-smoke`
//! job.
//!
//! Run: `cargo bench --bench fabric`

use lsgd::simnet::{
    des, fabric, ClusterModel, FabricConfig, FabricModel, NetConfig, NetModel, PerturbConfig,
    RoutingPolicy,
};
use lsgd::topology::Topology;
use lsgd::util::bench::{enforce_baseline_from_env, smoke_mode, Harness};

fn two_tier(oversub: f64) -> FabricConfig {
    FabricConfig { model: FabricModel::TwoTier, oversub, ..Default::default() }
}

fn three_tier(oversub: f64, pods: usize, routing: RoutingPolicy) -> FabricConfig {
    FabricConfig { model: FabricModel::ThreeTier { pods }, oversub, routing }
}

fn main() {
    let smoke = smoke_mode();
    let mut h = if smoke { Harness::quick() } else { Harness::default() };
    println!("# fabric — shared-fabric contention hot path");

    let m = ClusterModel::paper_k80();
    let topo = Topology::new(64, 4).unwrap();

    // allocator throughput: one max–min solve of the 256-rank flat
    // ring's flow set over the 64-group graph (the per-round cost of
    // the contended CSGD replay)
    let sizes = vec![4usize; 64];
    let fab = fabric::Fabric::two_tier(&sizes, 2.0);
    let flows = fab.flat_allreduce_flows(&sizes, 1.0);
    let routes: Vec<Vec<usize>> = flows.iter().map(|f| f.route.clone()).collect();
    h.bench("fabric/maxmin/64g_256flows", || {
        fabric::max_min_rates(fab.caps(), &routes)
    });

    // the 3-tier twin: same flow set, deeper graph (5-hop crossing
    // routes over 4 pods), so the solve touches ~2x the links
    let fab3 = fabric::Fabric::three_tier(&sizes, 2.0, 4);
    let flows3 = fab3.flat_allreduce_flows(&sizes, 1.0);
    let routes3: Vec<Vec<usize>> = flows3.iter().map(|f| f.route.clone()).collect();
    h.bench("fabric/maxmin_3tier/64g_4pod_256flows", || {
        fabric::max_min_rates(fab3.caps(), &routes3)
    });

    // contended closed-form DES steps (oversub 2): the LSGD row routes
    // the communicator ring, the CSGD row the full 256-rank flat ring
    let fabcfg = two_tier(2.0);
    h.bench("fabric/lsgd_2tier_step/64x4x3", || {
        des::run_lsgd_fabric(&m, &topo, 3, &fabcfg).unwrap().makespan
    });
    h.bench("fabric/csgd_2tier_step/64x4x3", || {
        des::run_csgd_fabric(&m, &topo, 3, &fabcfg).unwrap().makespan
    });

    // routing-policy cost on the 3-tier graph: deterministic single
    // plane vs the seeded ECMP hash per crossing flow — the delta is
    // the per-flow route-choice overhead, not the solve itself
    let det3 = three_tier(2.0, 4, RoutingPolicy::Deterministic);
    h.bench("fabric/csgd_3tier_det_step/64x4x3", || {
        des::run_csgd_fabric(&m, &topo, 3, &det3).unwrap().makespan
    });
    let ecmp3 = three_tier(2.0, 4, RoutingPolicy::Ecmp);
    h.bench("fabric/csgd_3tier_ecmp_step/64x4x3", || {
        des::run_csgd_fabric(&m, &topo, 3, &ecmp3).unwrap().makespan
    });

    // contended packet steps: fair-sharing plus the seeded per-message
    // draws — the uncontended twins live in benches/netsim.rs
    let mut p = PerturbConfig::default();
    p.net = NetConfig { model: NetModel::Packet, jitter: 0.2, reorder: 0.05, chunk: 1 };
    p.fabric = two_tier(2.0);
    h.bench("fabric/lsgd_packet_2tier_step/64x4x3", || {
        des::run_lsgd_perturbed(&m, &topo, 3, &p).unwrap().makespan
    });
    h.bench("fabric/csgd_packet_2tier_step/64x4x3", || {
        des::run_csgd_perturbed(&m, &topo, 3, &p).unwrap().makespan
    });

    println!("\n{}", h.csv());
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/BENCH_fabric.json", h.json()).unwrap();
    println!("→ bench_results/BENCH_fabric.json");
    enforce_baseline_from_env(&h.results);
}
