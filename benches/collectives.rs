//! Bench: the L3 collective primitives — the communicator rank's hot
//! path. Measures effective bandwidth of the fixed-order reductions
//! and the ring-allreduce baseline over paper-sized buffers
//! (ResNet-50 ≈ 25.6M f32 ≈ 102 MB).
//!
//! Run: `cargo bench --bench collectives`
//!
//! CI fast mode (`BENCH_SMOKE=1`) drops the 25.6M payload and uses the
//! quick harness budget; results land in
//! `bench_results/BENCH_collectives.json` and are checked against the
//! ceilings in `benches/baseline.json` when `BENCH_BASELINE` is set.

use lsgd::collective;
use lsgd::data::Rng;
use lsgd::util::bench::{enforce_baseline_from_env, smoke_mode, Harness};

fn rand_vec(seed: u64, n: usize) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.f64() as f32 - 0.5).collect()
}

fn main() {
    let smoke = smoke_mode();
    let mut h = if smoke { Harness::quick() } else { Harness::default() };
    println!("# collectives — fixed-order reductions + ring baseline");

    // sizes: tiny model, small model, ResNet-50-sized (the paper's payload)
    let sizes: &[(&str, usize)] = if smoke {
        &[("134k", 134_400), ("3.7M", 3_696_128)]
    } else {
        &[("134k", 134_400), ("3.7M", 3_696_128), ("25.6M", 25_600_000)]
    };
    for &(label, n) in sizes {
        let a = rand_vec(1, n);
        let b = rand_vec(2, n);
        let mut acc = a.clone();
        let s = h.bench(&format!("add_assign/{label}"), || {
            collective::add_assign(&mut acc, &b);
            acc[0]
        });
        let gbps = (n as f64 * 4.0 * 3.0) / s.median / 1e9; // r+r+w
        println!("    → {gbps:.2} GB/s effective");
    }

    // K-way fold (the local Reduce of Alg. 3 line 6) at paper group size
    let n = 3_696_128;
    let bufs: Vec<Vec<f32>> = (0..4u64).map(|i| rand_vec(10 + i, n)).collect();
    let refs: Vec<&[f32]> = bufs.iter().map(|v| v.as_slice()).collect();
    let serial = h.bench("reduce_scaled/4way/3.7M", || collective::reduce_scaled(&refs, 0.25));
    let serial_median = serial.median;

    // chunk-parallel fold: same association per element, bitwise-equal
    // output (the global fold of the thread-per-rank engine)
    let cores = std::thread::available_parallelism().map(|x| x.get()).unwrap_or(1);
    let mut thread_counts = vec![2usize, 4, cores];
    thread_counts.sort_unstable();
    thread_counts.dedup(); // cores may be 2 or 4 — avoid duplicate rows
    for threads in thread_counts {
        let s = h.bench(&format!("reduce_scaled_par/4way/3.7M/{threads}t"), || {
            collective::reduce_scaled_par(&refs, 0.25, threads)
        });
        println!(
            "    → {:.2}× vs serial fold (bitwise-identical result)",
            serial_median / s.median
        );
    }
    assert_eq!(
        collective::reduce_scaled_par(&refs, 0.25, cores),
        collective::reduce_scaled(&refs, 0.25),
        "chunk-parallel fold must be bitwise-identical"
    );

    // hierarchical (LSGD) vs flat association at 8 workers
    let bufs8: Vec<Vec<f32>> = (0..8u64).map(|i| rand_vec(20 + i, n)).collect();
    let refs8: Vec<&[f32]> = bufs8.iter().map(|v| v.as_slice()).collect();
    h.bench("flat_allreduce/8way/3.7M", || collective::flat_allreduce(&refs8));
    let grouped: Vec<Vec<&[f32]>> = (0..2)
        .map(|g| bufs8[g * 4..(g + 1) * 4].iter().map(|v| v.as_slice()).collect())
        .collect();
    h.bench("hierarchical_allreduce/2x4/3.7M", || {
        collective::hierarchical_allreduce(&grouped, 8)
    });

    // ring allreduce (the CSGD baseline's real data movement)
    for ranks in [2usize, 4, 8] {
        let mut ring_bufs: Vec<Vec<f32>> = (0..ranks as u64).map(|i| rand_vec(30 + i, n)).collect();
        h.bench(&format!("ring_allreduce/{ranks}ranks/3.7M"), || {
            collective::ring_allreduce(&mut ring_bufs, 1.0 / ranks as f32);
            ring_bufs[0][0]
        });
    }

    // broadcast (Alg. 3 line 9)
    let src = rand_vec(40, n);
    let mut d1 = vec![0.0f32; n];
    let mut d2 = vec![0.0f32; n];
    let mut d3 = vec![0.0f32; n];
    let mut d4 = vec![0.0f32; n];
    h.bench("broadcast/4dst/3.7M", || {
        collective::broadcast(&src, &mut [&mut d1, &mut d2, &mut d3, &mut d4]);
        d1[0]
    });

    println!("\n{}", h.csv());
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/BENCH_collectives.json", h.json()).unwrap();
    println!("→ bench_results/BENCH_collectives.json");
    enforce_baseline_from_env(&h.results);
}
