//! Bench harness for **Fig. 6**: scaling efficiency (% of perfect
//! linear) for LSGD and CSGD, 4 → 256 workers.
//!
//! Paper numbers to land on (asserted):
//!   * CSGD: 98.7 % @ 8 workers, dropping to 63.8 % @ 256;
//!   * LSGD: ≈100 % up to 32 workers, 93.1 % @ 256.
//!
//! Also sweeps the I/O window (the ablation DESIGN.md calls out): the
//! paper's §5.4 prediction — "LSGD will show better linear scalability
//! when we use bigger data [longer loads]" — is checked by varying
//! `t_io` and watching the 256-worker efficiency endpoint.
//!
//! Run: `cargo bench --bench fig6_efficiency`

use lsgd::metrics::{FigureSeries, ScalingRow};
use lsgd::simnet::{self, ClusterModel};
use lsgd::topology::Topology;

fn efficiency_series(m: &ClusterModel) -> FigureSeries {
    let base_c = simnet::step_time_csgd(m, &Topology::new(1, 4).unwrap()).total;
    let base_l = simnet::step_time_lsgd(m, &Topology::new(1, 4).unwrap()).total;
    let mut s = FigureSeries::new("Fig. 6 — scaling efficiency (%)");
    for g in [1usize, 2, 4, 8, 16, 32, 64] {
        let topo = Topology::new(g, 4).unwrap();
        let c = simnet::step_time_csgd(m, &topo);
        let l = simnet::step_time_lsgd(m, &topo);
        s.push(ScalingRow {
            workers: topo.num_workers(),
            groups: g,
            algo: "csgd".into(),
            step_seconds: c.total,
            throughput: simnet::throughput(m, &topo, c.total),
            comm_seconds: c.global_allreduce,
            comm_fraction: c.global_allreduce / c.total,
            efficiency_pct: 100.0 * base_c / c.total,
        });
        s.push(ScalingRow {
            workers: topo.num_workers(),
            groups: g,
            algo: "lsgd".into(),
            step_seconds: l.total,
            throughput: simnet::throughput(m, &topo, l.total),
            comm_seconds: l.global_exposed,
            comm_fraction: l.global_exposed / l.total,
            efficiency_pct: 100.0 * base_l / l.total,
        });
    }
    s
}

fn main() {
    let m = ClusterModel::paper_k80();
    let series = efficiency_series(&m);
    print!("{}", series.to_table());
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/fig6.csv", series.to_csv()).unwrap();
    println!("→ bench_results/fig6.csv");

    // paper endpoints, asserted (tolerance ±1 %)
    let eff = |algo: &str, workers: usize| {
        series
            .rows
            .iter()
            .find(|r| r.algo == algo && r.workers == workers)
            .unwrap()
            .efficiency_pct
    };
    let checks = [
        ("csgd", 8, 98.7),
        ("csgd", 256, 63.8),
        ("lsgd", 256, 93.1),
    ];
    for (algo, w, want) in checks {
        let got = eff(algo, w);
        assert!(
            (got - want).abs() < 1.0,
            "{algo}@{w}: {got:.1}% vs paper {want}%"
        );
        println!("paper check OK: {algo}@{w} workers = {got:.1}% (paper: {want}%)");
    }

    // ablation: the I/O window size drives LSGD's endpoint (§5.4)
    println!("\n# ablation — LSGD efficiency @256 workers vs data-loading window");
    println!("{:>8} {:>12} {:>10}", "t_io(s)", "exposed(s)", "eff_%");
    for t_io in [0.0, 0.15, 0.35, 0.55, 0.70, 1.0] {
        let mut mi = ClusterModel::paper_k80();
        mi.t_io = t_io;
        let base = simnet::step_time_lsgd(&mi, &Topology::new(1, 4).unwrap()).total;
        let s = simnet::step_time_lsgd(&mi, &Topology::new(64, 4).unwrap());
        println!("{:>8.2} {:>12.4} {:>10.1}", t_io, s.global_exposed, 100.0 * base / s.total);
    }
    println!("(longer loads hide the whole allreduce → efficiency → 100 %, the paper's prediction)");
}
