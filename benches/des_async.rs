//! Bench guard: the de-synchronized event core prices big perturbed
//! runs fast — with and without a global barrier.
//!
//! Two rows drive the same straggler-perturbed schedule through the
//! per-entity timeline core: `rendezvous_step` (synchronous `lsgd` —
//! every step an all-group rendezvous, the event-heavy worst case) and
//! `barrier_free_step` (`lasgd` — group-local rendezvous only, plus
//! the parked-update retry machinery of the one-step-stale exchange).
//! Stragglers desynchronize the group clocks, so the calendar queue
//! sees scattered timestamps rather than lockstep barriers. Smoke mode
//! (`BENCH_SMOKE=1`) runs 64×4; the full rows run 256×4.
//!
//! Run: `cargo bench --bench des_async`

use lsgd::sched::scheduler::{Lasgd, Lsgd, RendezvousScope};
use lsgd::simnet::{des, ClusterModel, PerturbConfig};
use lsgd::topology::Topology;
use lsgd::util::bench::{enforce_baseline_from_env, smoke_mode, Harness};

fn main() {
    let smoke = smoke_mode();
    let mut h = if smoke { Harness::quick() } else { Harness::default() };
    println!("# des_async — rendezvous-heavy vs barrier-free event core");

    let m = ClusterModel::paper_k80();
    let groups = if smoke { 64 } else { 256 };
    let steps = 6;
    let topo = Topology::new(groups, 4).unwrap();
    let mut p = PerturbConfig::default();
    p.straggle_prob = 0.3;
    p.straggle_factor = 3.0;
    p.trace = false;

    // every step joins all group timelines at the global rendezvous —
    // maximum barrier events per step
    h.bench(&format!("des_async/rendezvous_step/{groups}x4x{steps}"), || {
        des::run_sched_perturbed(&m, &topo, steps, &p, &Lsgd).unwrap().makespan
    });

    // group-local rendezvous only: the cross-group exchange runs off
    // the critical path and updates park on the one-step-stale gate
    let lasgd = Lasgd { alpha: 0.5, scope: RendezvousScope::GroupLocal };
    h.bench(&format!("des_async/barrier_free_step/{groups}x4x{steps}"), || {
        des::run_sched_perturbed(&m, &topo, steps, &p, &lasgd).unwrap().makespan
    });

    println!("\n{}", h.csv());
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/BENCH_des_async.json", h.json()).unwrap();
    println!("→ bench_results/BENCH_des_async.json");
    enforce_baseline_from_env(&h.results);
}
