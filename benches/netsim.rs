//! Bench guard: packet-level network emulation must stay cheap enough
//! to price every collective of a DES step at message granularity.
//!
//! The hot path is `net::sim_rounds` — one completion event and a
//! seeded hash per (sub-)message when jitter is on. The flat 256-rank
//! ring is the worst case the repo simulates today (~130k messages per
//! step); the `*_packet_step` rows replay whole DES steps so a
//! regression in the event loop, the draw path, or the per-phase
//! accounting shows up where it is actually paid. Ceilings live in
//! `benches/baseline.json` and are enforced by CI's `bench-smoke` job.
//!
//! Run: `cargo bench --bench netsim`

use lsgd::simnet::{des, net, AllreduceAlgo, ClusterModel, NetConfig, NetModel, PerturbConfig};
use lsgd::topology::Topology;
use lsgd::util::bench::{enforce_baseline_from_env, smoke_mode, Harness};

fn packet(jitter: f64) -> NetConfig {
    NetConfig { model: NetModel::Packet, jitter, reorder: 0.05, chunk: 1 }
}

fn main() {
    let smoke = smoke_mode();
    let mut h = if smoke { Harness::quick() } else { Harness::default() };
    println!("# netsim — packet-level collective emulation hot path");

    let m = ClusterModel::paper_k80();
    let cfg = packet(0.2);
    let seed = 0x57A6u64;

    // single collectives, jittered: ~8k messages (ring/64), ~1k (rhd),
    // ~130k (flat ring over 256 workers)
    h.bench("netsim/ring_allreduce/64r/102MB", || {
        let mut acc = net::NetAcc::default();
        net::allreduce(
            AllreduceAlgo::Ring,
            m.comm_inter,
            64,
            m.grad_bytes,
            &cfg,
            seed,
            net::Phase::GlobalAllreduce,
            0,
            &mut acc,
        )
    });
    h.bench("netsim/rhd_allreduce/64r/102MB", || {
        let mut acc = net::NetAcc::default();
        net::allreduce(
            AllreduceAlgo::RecursiveHalvingDoubling,
            m.comm_inter,
            64,
            m.grad_bytes,
            &cfg,
            seed,
            net::Phase::GlobalAllreduce,
            0,
            &mut acc,
        )
    });
    h.bench("netsim/flat_ring/256r/102MB", || {
        let mut acc = net::NetAcc::default();
        net::allreduce(
            AllreduceAlgo::Ring,
            m.inter,
            256,
            m.grad_bytes,
            &cfg,
            seed,
            net::Phase::FlatAllreduce,
            0,
            &mut acc,
        )
    });

    // whole DES steps at the paper's scale, every collective priced at
    // message granularity
    let topo = Topology::new(64, 4).unwrap();
    let mut p = PerturbConfig::default();
    p.net = packet(0.2);
    h.bench("netsim/lsgd_packet_step/64x4x3", || {
        des::run_lsgd_perturbed(&m, &topo, 3, &p).unwrap().makespan
    });
    h.bench("netsim/csgd_packet_step/64x4x3", || {
        des::run_csgd_perturbed(&m, &topo, 3, &p).unwrap().makespan
    });

    println!("\n{}", h.csv());
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/BENCH_netsim.json", h.json()).unwrap();
    println!("→ bench_results/BENCH_netsim.json");
    enforce_baseline_from_env(&h.results);
}
