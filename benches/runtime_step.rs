//! Bench: the PJRT runtime hot path — grad_step / sgd_update /
//! reduce / eval per preset (requires `make artifacts`).
//!
//! This is the end-to-end per-table bench for the *real* execution
//! layer: every number here feeds the `scaling_sweep` calibration and
//! EXPERIMENTS.md §Perf. The fused-update and reduce rows measure the
//! L1 Pallas kernels through their AOT-lowered HLO.
//!
//! Run: `cargo bench --bench runtime_step`

use lsgd::data::Rng;
use lsgd::runtime::Engine;
use lsgd::util::bench::Harness;

fn rand_vec(seed: u64, n: usize) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| (rng.f64() as f32 - 0.5) * 0.1).collect()
}

fn rand_tokens(seed: u64, n: usize, vocab: i32) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.below(vocab as u64) as i32).collect()
}

fn bench_preset(h: &mut Harness, preset: &str) {
    let engine = match Engine::load(std::path::Path::new("artifacts"), preset) {
        Ok(e) => e,
        Err(e) => {
            println!("skipping preset {preset}: {e:#}");
            return;
        }
    };
    let p = engine.param_count();
    let vocab = engine.manifest.config.vocab as i32;
    let ntok = engine.micro_batch() * engine.tokens_per_sample();
    let w = engine.init_params().unwrap();
    let m = vec![0.0f32; p];
    let g = rand_vec(1, p);
    let a = rand_vec(2, p);
    let b = rand_vec(3, p);
    let toks = rand_tokens(4, ntok, vocab);

    println!("\n# preset {preset}: {p} params, micro_batch {}", engine.micro_batch());
    let s = h.bench(&format!("{preset}/grad_step"), || engine.grad_step(&w, &toks).unwrap());
    let tokens_s = (engine.micro_batch() * (engine.tokens_per_sample() - 1)) as f64 / s.median;
    println!("    → {tokens_s:.0} tokens/s fwd+bwd");
    let s = h.bench(&format!("{preset}/sgd_update"), || {
        engine.sgd_update(&w, &m, &g, 0.1).unwrap()
    });
    println!("    → {:.2} GB/s (5 streams)", p as f64 * 4.0 * 5.0 / s.median / 1e9);
    let s = h.bench(&format!("{preset}/reduce2"), || engine.reduce2(&a, &b, 0.5).unwrap());
    println!("    → {:.2} GB/s (3 streams)", p as f64 * 4.0 * 3.0 / s.median / 1e9);
    let refs: Vec<&[f32]> = vec![&a, &b, &g, &w];
    h.bench(&format!("{preset}/reduce_fold/4way"), || {
        engine.reduce_fold(&refs, 0.25).unwrap()
    });
    h.bench(&format!("{preset}/eval_step"), || engine.eval_step(&w, &toks).unwrap());
}

fn main() {
    // quick budget: the base preset's grad_step runs ~6 s/iteration on
    // this 1-core testbed; the default 2 s budget would still do 5
    // iterations each but warmup×3 adds up across 15 rows.
    let mut h = Harness::quick();
    for preset in ["tiny", "small", "base"] {
        bench_preset(&mut h, preset);
    }
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/runtime_step.csv", h.csv()).unwrap();
    println!("\n→ bench_results/runtime_step.csv");
}
