//! Bench: the runtime hot path — grad_step / sgd_update / reduce /
//! eval per preset — plus the headline comparison: **serial vs
//! thread-per-rank full training steps**.
//!
//! The serial engine executes every worker's compute phase back to
//! back on one thread; the thread-per-rank engine runs one OS thread
//! per worker and per communicator, so on a multi-core host the
//! per-step wall-clock should drop roughly with the worker count
//! (until memory bandwidth saturates) while the trajectory stays
//! bitwise-identical (asserted here on the measured runs).
//!
//! Run: `cargo bench --bench runtime_step`
//!
//! CI runs this in fast mode (`BENCH_SMOKE=1`): fewer presets and
//! topologies, quick harness budget. Results are always written to
//! `bench_results/BENCH_runtime.json`; when `BENCH_BASELINE` names a
//! baseline file (CI: `benches/baseline.json`), any bench whose median
//! exceeds its baseline ceiling by >25 % fails the run.

use lsgd::config::{Algo, ExperimentConfig};
use lsgd::data::Rng;
use lsgd::runtime::Engine;
use lsgd::sched::Trainer;
use lsgd::topology::Topology;
use lsgd::util::bench::{enforce_baseline_from_env, smoke_mode, Harness};

fn rand_vec(seed: u64, n: usize) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| (rng.f64() as f32 - 0.5) * 0.1).collect()
}

fn rand_tokens(seed: u64, n: usize, vocab: i32) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.below(vocab as u64) as i32).collect()
}

fn bench_preset(h: &mut Harness, preset: &str) {
    let engine = match Engine::load(std::path::Path::new("artifacts"), preset) {
        Ok(e) => e,
        Err(e) => {
            println!("skipping preset {preset}: {e:#}");
            return;
        }
    };
    let p = engine.param_count();
    let vocab = engine.manifest.config.vocab as i32;
    let ntok = engine.micro_batch() * engine.tokens_per_sample();
    let w = engine.init_params().unwrap();
    let m = vec![0.0f32; p];
    let g = rand_vec(1, p);
    let a = rand_vec(2, p);
    let b = rand_vec(3, p);
    let toks = rand_tokens(4, ntok, vocab);

    println!("\n# preset {preset}: {p} params, micro_batch {}", engine.micro_batch());
    let s = h.bench(&format!("{preset}/grad_step"), || engine.grad_step(&w, &toks).unwrap());
    let tokens_s = (engine.micro_batch() * (engine.tokens_per_sample() - 1)) as f64 / s.median;
    println!("    → {tokens_s:.0} tokens/s fwd+bwd");
    let s = h.bench(&format!("{preset}/sgd_update"), || {
        engine.sgd_update(&w, &m, &g, 0.1).unwrap()
    });
    println!("    → {:.2} GB/s (5 streams)", p as f64 * 4.0 * 5.0 / s.median / 1e9);
    let s = h.bench(&format!("{preset}/reduce2"), || engine.reduce2(&a, &b, 0.5).unwrap());
    println!("    → {:.2} GB/s (3 streams)", p as f64 * 4.0 * 3.0 / s.median / 1e9);
    let refs: Vec<&[f32]> = vec![&a, &b, &g, &w];
    h.bench(&format!("{preset}/reduce_fold/4way"), || {
        engine.reduce_fold(&refs, 0.25).unwrap()
    });
    h.bench(&format!("{preset}/eval_step"), || engine.eval_step(&w, &toks).unwrap());
}

/// The acceptance bench: full LSGD/CSGD steps, serial engine vs the
/// thread-per-rank engine, same topology and data. Returns the two
/// medians so main() can print the speedup.
fn bench_engines(h: &mut Harness, preset: &str, groups: usize, wpg: usize, algo: Algo) {
    let engine = Engine::host(preset).expect("host preset");
    let steps = 4;
    let mk_cfg = || {
        let mut c = ExperimentConfig::default();
        c.algo = algo;
        c.topology = Topology::new(groups, wpg).unwrap();
        c.steps = steps;
        c.data.train_samples = 1024;
        c.data.val_samples = 64;
        c
    };
    let label = format!("{algo}/{groups}x{wpg}/{preset}");
    let mut serial_sums = None;
    let s = h.bench(&format!("step/serial/{label}"), || {
        let mut t = Trainer::new(&engine, mk_cfg(), false).unwrap();
        let r = t.run().unwrap();
        serial_sums = Some(r.step_checksums.clone());
        r.steps
    });
    let serial_step = s.median / steps as f64;
    let mut par_sums = None;
    let s = h.bench(&format!("step/thread-per-rank/{label}"), || {
        let mut t = Trainer::new(&engine, mk_cfg(), false).unwrap();
        let r = t.run_parallel().unwrap();
        par_sums = Some(r.step_checksums.clone());
        r.steps
    });
    let par_step = s.median / steps as f64;
    assert_eq!(
        serial_sums, par_sums,
        "engines disagree — the determinism contract is broken"
    );
    println!(
        "    → per-step: serial {:.2} ms, thread-per-rank {:.2} ms  ({:.2}× speedup, bitwise-identical)",
        serial_step * 1e3,
        par_step * 1e3,
        serial_step / par_step
    );
}

fn main() {
    let smoke = smoke_mode();
    let mut h = Harness::quick();
    let presets: &[&str] = if smoke { &["tiny"] } else { &["tiny", "small", "base"] };
    for preset in presets {
        bench_preset(&mut h, preset);
    }

    println!("\n# full steps: serial vs thread-per-rank (same data, same trajectory)");
    let cores = std::thread::available_parallelism().map(|x| x.get()).unwrap_or(1);
    println!("  ({cores} cpu threads available)");
    if smoke {
        bench_engines(&mut h, "tiny", 2, 2, Algo::Lsgd);
        bench_engines(&mut h, "tiny", 2, 2, Algo::Csgd);
    } else {
        bench_engines(&mut h, "small", 2, 2, Algo::Lsgd);
        bench_engines(&mut h, "small", 2, 2, Algo::Csgd);
        bench_engines(&mut h, "small", 2, 4, Algo::Lsgd);
        bench_engines(&mut h, "base", 2, 2, Algo::Lsgd);
    }

    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/runtime_step.csv", h.csv()).unwrap();
    std::fs::write("bench_results/BENCH_runtime.json", h.json()).unwrap();
    println!("\n→ bench_results/runtime_step.csv, bench_results/BENCH_runtime.json");
    enforce_baseline_from_env(&h.results);
}
